//! A minimal fixed-size thread pool (in-tree substrate; DESIGN.md §3).
//!
//! The vendored dependency set has no rayon, so the small slice this
//! project needs is implemented here: a process-wide pool of worker
//! threads plus a *scoped* batch API — [`ThreadPool::scoped`] runs a set
//! of jobs that may borrow from the caller's stack and blocks until all
//! of them have finished. The transfer engine uses it to split large
//! plane/block copies into chunks ([`crate::marionette::transfer`]).
//!
//! Scoped jobs must not themselves call [`ThreadPool::scoped`] on the
//! same pool: with every worker parked inside the outer batch, the
//! inner batch could never be picked up.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

type Job = Box<dyn FnOnce() + Send + 'static>;

struct State {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct Shared {
    queue: Mutex<State>,
    cv: Condvar,
}

/// Fixed set of worker threads draining a shared job queue.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn a pool with `threads` workers (at least one).
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(State { jobs: VecDeque::new(), shutdown: false }),
            cv: Condvar::new(),
        });
        let workers = (0..threads)
            .map(|_| {
                let sh = shared.clone();
                std::thread::spawn(move || worker_loop(sh))
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// The process-wide pool, sized to the available parallelism (min 2).
    pub fn global() -> &'static ThreadPool {
        static POOL: OnceLock<ThreadPool> = OnceLock::new();
        POOL.get_or_init(|| {
            let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
            ThreadPool::new(n.max(2))
        })
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    fn submit(&self, job: Job) {
        let mut g = self.shared.queue.lock().unwrap();
        g.jobs.push_back(job);
        drop(g);
        self.shared.cv.notify_one();
    }

    /// Run every job to completion, blocking the caller until the last
    /// one has finished. Jobs may borrow from the caller's stack; the
    /// borrow is sound because this function never returns (panic
    /// included) before every job has executed.
    pub fn scoped<'a>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'a>>) {
        if jobs.is_empty() {
            return;
        }
        let latch = Arc::new(Latch::new(jobs.len()));
        let panicked = Arc::new(AtomicBool::new(false));
        for job in jobs {
            // SAFETY: `latch.wait()` below blocks until this job has run
            // (the latch counts down even when the job panics), so every
            // borrow captured in `job` outlives its use on the worker.
            let job: Box<dyn FnOnce() + Send + 'static> = unsafe { std::mem::transmute(job) };
            let latch = latch.clone();
            let panicked = panicked.clone();
            self.submit(Box::new(move || {
                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                    panicked.store(true, Ordering::Relaxed);
                }
                latch.count_down();
            }));
        }
        latch.wait();
        if panicked.load(Ordering::Relaxed) {
            panic!("thread-pool job panicked");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.queue.lock().unwrap().shutdown = true;
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut g = sh.queue.lock().unwrap();
            loop {
                if let Some(j) = g.jobs.pop_front() {
                    break j;
                }
                if g.shutdown {
                    return;
                }
                g = sh.cv.wait(g).unwrap();
            }
        };
        job();
    }
}

/// Count-down latch: `wait` blocks until `count_down` has been called
/// the initial-count number of times.
struct Latch {
    state: Mutex<usize>,
    cv: Condvar,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch { state: Mutex::new(n), cv: Condvar::new() }
    }

    fn count_down(&self) {
        let mut g = self.state.lock().unwrap();
        *g -= 1;
        if *g == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut g = self.state.lock().unwrap();
        while *g > 0 {
            g = self.cv.wait(g).unwrap();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let hits = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..64)
            .map(|_| {
                Box::new(|| {
                    hits.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.scoped(jobs);
        assert_eq!(hits.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn jobs_may_borrow_stack_data() {
        let pool = ThreadPool::new(3);
        let data: Vec<u64> = (0..1000).collect();
        let sums: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|c| {
                let chunk = &data[c * 250..(c + 1) * 250];
                let slot = &sums[c];
                Box::new(move || {
                    let s: u64 = chunk.iter().sum();
                    slot.store(s as usize, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.scoped(jobs);
        let total: usize = sums.iter().map(|s| s.load(Ordering::Relaxed)).sum();
        assert_eq!(total as u64, (0..1000u64).sum());
    }

    #[test]
    #[should_panic(expected = "thread-pool job panicked")]
    fn panics_propagate_after_batch_completes() {
        let pool = ThreadPool::new(2);
        let ok = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
            Box::new(|| panic!("boom")),
            Box::new(|| {
                ok.fetch_add(1, Ordering::Relaxed);
            }),
        ];
        pool.scoped(jobs);
    }

    #[test]
    fn global_pool_has_multiple_workers() {
        assert!(ThreadPool::global().workers() >= 2);
    }

    #[test]
    fn sequential_batches_reuse_workers() {
        let pool = ThreadPool::new(2);
        for round in 0..10 {
            let hit = AtomicUsize::new(0);
            pool.scoped(vec![Box::new(|| {
                hit.fetch_add(1, Ordering::Relaxed);
            }) as Box<dyn FnOnce() + Send + '_>]);
            assert_eq!(hit.load(Ordering::Relaxed), 1, "round {round}");
        }
    }
}

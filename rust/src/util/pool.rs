//! Pools (in-tree substrate; DESIGN.md §3, §5): a minimal fixed-size
//! thread pool plus a generic recycling object pool.
//!
//! The vendored dependency set has no rayon, so the small slice this
//! project needs is implemented here: a process-wide pool of worker
//! threads plus a *scoped* batch API — [`ThreadPool::scoped`] runs a set
//! of jobs that may borrow from the caller's stack and blocks until all
//! of them have finished. The transfer engine uses it to split large
//! plane/block copies into chunks ([`crate::marionette::transfer`]).
//!
//! Scoped jobs must not themselves call [`ThreadPool::scoped`] on the
//! same pool: with every worker parked inside the outer batch, the
//! inner batch could never be picked up.
//!
//! [`ObjectPool`] / [`Recycler`] are the object-level recycling pair
//! under the memory strategy in DESIGN.md §5: `checkout()` hands out a
//! warm object (or makes a fresh one) behind an RAII [`Recycler`]
//! handle that checks it back in on drop, capacity intact. The pipeline
//! uses it for per-event staging collections; byte-level recycling is
//! [`crate::marionette::memory::PoolContext`].

use std::collections::VecDeque;
use std::ops::{Deref, DerefMut};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

type Job = Box<dyn FnOnce() + Send + 'static>;

struct State {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct Shared {
    queue: Mutex<State>,
    cv: Condvar,
}

/// Fixed set of worker threads draining a shared job queue.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn a pool with `threads` workers (at least one).
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(State { jobs: VecDeque::new(), shutdown: false }),
            cv: Condvar::new(),
        });
        let workers = (0..threads)
            .map(|_| {
                let sh = shared.clone();
                std::thread::spawn(move || worker_loop(sh))
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// The process-wide pool, sized to the available parallelism (min 2).
    pub fn global() -> &'static ThreadPool {
        static POOL: OnceLock<ThreadPool> = OnceLock::new();
        POOL.get_or_init(|| {
            let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
            ThreadPool::new(n.max(2))
        })
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    fn submit(&self, job: Job) {
        let mut g = self.shared.queue.lock().unwrap();
        g.jobs.push_back(job);
        drop(g);
        self.shared.cv.notify_one();
    }

    /// Run every job to completion, blocking the caller until the last
    /// one has finished. Jobs may borrow from the caller's stack; the
    /// borrow is sound because this function never returns (panic
    /// included) before every job has executed.
    pub fn scoped<'a>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'a>>) {
        if jobs.is_empty() {
            return;
        }
        let latch = Arc::new(Latch::new(jobs.len()));
        let panicked = Arc::new(AtomicBool::new(false));
        for job in jobs {
            // SAFETY: `latch.wait()` below blocks until this job has run
            // (the latch counts down even when the job panics), so every
            // borrow captured in `job` outlives its use on the worker.
            let job: Box<dyn FnOnce() + Send + 'static> = unsafe { std::mem::transmute(job) };
            let latch = latch.clone();
            let panicked = panicked.clone();
            self.submit(Box::new(move || {
                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                    panicked.store(true, Ordering::Relaxed);
                }
                latch.count_down();
            }));
        }
        latch.wait();
        if panicked.load(Ordering::Relaxed) {
            panic!("thread-pool job panicked");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.queue.lock().unwrap().shutdown = true;
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut g = sh.queue.lock().unwrap();
            loop {
                if let Some(j) = g.jobs.pop_front() {
                    break j;
                }
                if g.shutdown {
                    return;
                }
                g = sh.cv.wait(g).unwrap();
            }
        };
        job();
    }
}

/// Count-down latch: `wait` blocks until `count_down` has been called
/// the initial-count number of times.
struct Latch {
    state: Mutex<usize>,
    cv: Condvar,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch { state: Mutex::new(n), cv: Condvar::new() }
    }

    fn count_down(&self) {
        let mut g = self.state.lock().unwrap();
        *g -= 1;
        if *g == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut g = self.state.lock().unwrap();
        while *g > 0 {
            g = self.cv.wait(g).unwrap();
        }
    }
}

// ---------------------------------------------------------------------
// Object recycling: ObjectPool + Recycler
// ---------------------------------------------------------------------

/// Counters of an [`ObjectPool`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ObjectPoolStats {
    /// Checkouts served from the idle shelf.
    pub hits: usize,
    /// Checkouts that constructed a fresh object.
    pub misses: usize,
    /// Objects checked back in.
    pub returns: usize,
    /// Returns rejected by the idle bound (object dropped instead).
    pub dropped: usize,
}

/// A pool of reusable objects. [`ObjectPool::checkout`] pops an idle
/// object (or builds one with the constructor) and wraps it in a
/// [`Recycler`] that checks it back in on drop — so anything with
/// amortised internal capacity (collections, buffers) keeps that
/// capacity warm across uses instead of re-allocating per use.
pub struct ObjectPool<T: Send> {
    idle: Mutex<Vec<T>>,
    make: Box<dyn Fn() -> T + Send + Sync>,
    max_idle: usize,
    hits: AtomicUsize,
    misses: AtomicUsize,
    returns: AtomicUsize,
    dropped: AtomicUsize,
}

impl<T: Send> ObjectPool<T> {
    /// Pool with a default idle bound of 64 objects.
    pub fn new(make: impl Fn() -> T + Send + Sync + 'static) -> Arc<ObjectPool<T>> {
        Self::with_max_idle(make, 64)
    }

    /// Pool keeping at most `max_idle` objects parked; returns beyond
    /// the bound drop the object (its memory goes back to its context).
    pub fn with_max_idle(
        make: impl Fn() -> T + Send + Sync + 'static,
        max_idle: usize,
    ) -> Arc<ObjectPool<T>> {
        Arc::new(ObjectPool {
            idle: Mutex::new(Vec::new()),
            make: Box::new(make),
            max_idle,
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            returns: AtomicUsize::new(0),
            dropped: AtomicUsize::new(0),
        })
    }

    /// Draw an object; it returns to the pool when the handle drops.
    /// Takes the `Arc` handle by value — clone it to keep the pool:
    /// `pool.clone().checkout()`.
    pub fn checkout(self: Arc<Self>) -> Recycler<T> {
        let recycled = self.idle.lock().unwrap().pop();
        let item = match recycled {
            Some(t) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                t
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                (self.make)()
            }
        };
        Recycler { item: Some(item), pool: self }
    }

    /// Objects currently parked.
    pub fn idle(&self) -> usize {
        self.idle.lock().unwrap().len()
    }

    /// Snapshot the counters.
    pub fn stats(&self) -> ObjectPoolStats {
        ObjectPoolStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            returns: self.returns.load(Ordering::Relaxed),
            dropped: self.dropped.load(Ordering::Relaxed),
        }
    }
}

impl<T: Send> std::fmt::Debug for ObjectPool<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        write!(f, "ObjectPool(idle={} {s:?})", self.idle())
    }
}

/// RAII checkout handle: derefs to the pooled object and checks it back
/// in on drop (unless [`Recycler::detach`]ed).
pub struct Recycler<T: Send> {
    item: Option<T>,
    pool: Arc<ObjectPool<T>>,
}

impl<T: Send> Recycler<T> {
    /// Take the object out for good; it will not return to the pool.
    pub fn detach(mut self) -> T {
        self.item.take().expect("recycler item present until drop")
    }
}

impl<T: Send> Deref for Recycler<T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.item.as_ref().expect("recycler item present until drop")
    }
}

impl<T: Send> DerefMut for Recycler<T> {
    fn deref_mut(&mut self) -> &mut T {
        self.item.as_mut().expect("recycler item present until drop")
    }
}

impl<T: Send> Drop for Recycler<T> {
    fn drop(&mut self) {
        if let Some(t) = self.item.take() {
            let mut g = self.pool.idle.lock().unwrap();
            if g.len() < self.pool.max_idle {
                self.pool.returns.fetch_add(1, Ordering::Relaxed);
                g.push(t);
            } else {
                self.pool.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let hits = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..64)
            .map(|_| {
                Box::new(|| {
                    hits.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.scoped(jobs);
        assert_eq!(hits.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn jobs_may_borrow_stack_data() {
        let pool = ThreadPool::new(3);
        let data: Vec<u64> = (0..1000).collect();
        let sums: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|c| {
                let chunk = &data[c * 250..(c + 1) * 250];
                let slot = &sums[c];
                Box::new(move || {
                    let s: u64 = chunk.iter().sum();
                    slot.store(s as usize, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.scoped(jobs);
        let total: usize = sums.iter().map(|s| s.load(Ordering::Relaxed)).sum();
        assert_eq!(total as u64, (0..1000u64).sum());
    }

    #[test]
    #[should_panic(expected = "thread-pool job panicked")]
    fn panics_propagate_after_batch_completes() {
        let pool = ThreadPool::new(2);
        let ok = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
            Box::new(|| panic!("boom")),
            Box::new(|| {
                ok.fetch_add(1, Ordering::Relaxed);
            }),
        ];
        pool.scoped(jobs);
    }

    #[test]
    fn global_pool_has_multiple_workers() {
        assert!(ThreadPool::global().workers() >= 2);
    }

    #[test]
    fn sequential_batches_reuse_workers() {
        let pool = ThreadPool::new(2);
        for round in 0..10 {
            let hit = AtomicUsize::new(0);
            pool.scoped(vec![Box::new(|| {
                hit.fetch_add(1, Ordering::Relaxed);
            }) as Box<dyn FnOnce() + Send + '_>]);
            assert_eq!(hit.load(Ordering::Relaxed), 1, "round {round}");
        }
    }

    #[test]
    fn object_pool_recycles_and_bounds_idle() {
        let made = Arc::new(AtomicUsize::new(0));
        let m = made.clone();
        let pool = ObjectPool::with_max_idle(
            move || {
                m.fetch_add(1, Ordering::Relaxed);
                Vec::<u8>::with_capacity(1024)
            },
            1,
        );
        {
            let mut a = pool.clone().checkout();
            a.push(7);
            let _b = pool.clone().checkout(); // second live object
        } // both return; idle bound 1 keeps one, drops one
        assert_eq!(made.load(Ordering::Relaxed), 2);
        assert_eq!(pool.idle(), 1);
        let s = pool.stats();
        assert_eq!((s.hits, s.misses, s.returns, s.dropped), (0, 2, 1, 1));
        // The survivor comes back warm (capacity intact, content stale —
        // callers own the reset policy).
        let c = pool.clone().checkout();
        assert!(c.capacity() >= 1024);
        assert_eq!(made.load(Ordering::Relaxed), 2);
        assert_eq!(pool.stats().hits, 1);
        let detached = c.detach();
        drop(detached);
        assert_eq!(pool.idle(), 0, "detached objects do not return");
    }

    /// Thread-pool + memory-pool contention stress: many scoped workers
    /// hammering one byte pool (PoolContext) and one object pool at
    /// once. Run via `ci.sh` with `MARIONETTE_STRESS=1` (or
    /// `cargo test -- --ignored`).
    #[test]
    #[ignore = "stress target; run with --ignored (ci.sh MARIONETTE_STRESS=1)"]
    fn thread_and_memory_pool_contention_stress() {
        use crate::marionette::buffer::ContextAwareVec;
        use crate::marionette::memory::{CountingInfo, Pool, PoolContext, PoolInfo};

        type Ctx = PoolContext<crate::marionette::memory::CountingContext>;

        let inner = CountingInfo::default();
        let bytes = PoolInfo(Pool::<crate::marionette::memory::CountingContext>::with_config(
            inner.clone(),
            8 << 20, // tight high water: trimming under contention
        ));
        let objects = {
            let info = bytes.clone();
            ObjectPool::with_max_idle(move || ContextAwareVec::<u64, Ctx>::new_in(info.clone()), 16)
        };

        let tp = ThreadPool::new(8);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..64)
            .map(|j| {
                let objects = objects.clone();
                let bytes = bytes.clone();
                Box::new(move || {
                    for round in 0..50 {
                        // Object-pool churn: grow a recycled vec to a
                        // job-dependent size, verify its tail.
                        let n = 64 + 37 * ((j + round) % 17);
                        let mut v = objects.clone().checkout();
                        v.clear();
                        for i in 0..n {
                            v.push((j * 1_000_000 + i) as u64);
                        }
                        assert_eq!(v[n - 1], (j * 1_000_000 + n - 1) as u64);
                        // Byte-pool churn: a short-lived buffer per round.
                        let scratch = ContextAwareVec::<u64, Ctx>::with_capacity_in(
                            n,
                            bytes.clone(),
                        );
                        assert!(scratch.capacity() >= n);
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        tp.scoped(jobs);

        // Scratch buffers all returned; the only blocks still checked
        // out are the ones held by idle pooled vecs (one buffer each).
        assert_eq!(bytes.0.outstanding(), objects.idle());
        // Release the object pool, then the byte pool: everything must
        // flow back to the counting heap with nothing leaked.
        drop(objects);
        assert_eq!(bytes.0.outstanding(), 0);
        drop(bytes);
        assert_eq!(inner.0.live_allocs(), 0, "leaked inner allocations");
        assert_eq!(inner.0.live_bytes(), 0, "leaked inner bytes");
    }
}

//! Pools (in-tree substrate; DESIGN.md §3, §5, §8): a work-stealing
//! task scheduler plus a generic recycling object pool.
//!
//! The vendored dependency set has no rayon, so the small slice this
//! project needs is implemented here. [`ThreadPool`] is a fixed set of
//! worker threads scheduled by work stealing (DESIGN.md §8): every
//! worker owns a private deque it pushes and pops **LIFO** (hot cache,
//! no contention with its siblings), external submissions land in a
//! shared injector queue, and an idle worker first drains the injector,
//! then steals **FIFO** from a sibling's deque — oldest task first, the
//! one whose data is coldest for its owner. Idle workers park on a
//! condvar; every submission performs a lock-drop/notify handshake so a
//! worker between its "queues are empty" check and its wait can never
//! miss the wakeup.
//!
//! Two submission APIs sit on top:
//!
//! * [`ThreadPool::spawn`] — fire-and-forget `'static` tasks (the
//!   coordinator's host event workers run on this).
//! * [`ThreadPool::scoped`] — run a batch of jobs that may borrow from
//!   the caller's stack, blocking until all of them have finished. The
//!   transfer engine uses it to split large plane/block copies into
//!   chunks ([`crate::marionette::transfer`]).
//!
//! Scoped jobs must not themselves call [`ThreadPool::scoped`] on the
//! same pool: with every worker parked inside the outer batch, the
//! inner batch could never be picked up. (Plain [`ThreadPool::spawn`]
//! from inside a job is fine — it pushes to the worker's own deque.)
//!
//! [`ObjectPool`] / [`Recycler`] are the object-level recycling pair
//! under the memory strategy in DESIGN.md §5: `checkout()` hands out a
//! warm object (or makes a fresh one) behind an RAII [`Recycler`]
//! handle that checks it back in on drop, capacity intact. The pipeline
//! uses it for per-event staging collections; byte-level recycling is
//! [`crate::marionette::memory::PoolContext`].

use std::cell::Cell;
use std::collections::VecDeque;
use std::ops::{Deref, DerefMut};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicIsize, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Scheduler counters of a [`ThreadPool`] (monotone).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ThreadPoolStats {
    /// Jobs submitted from outside the pool (landed in the injector).
    pub injected: usize,
    /// Jobs submitted by a worker of this pool (landed in its own deque).
    pub local_pushes: usize,
    /// Jobs taken FIFO from a sibling worker's deque.
    pub steals: usize,
    /// Jobs that finished executing (panicking jobs included).
    pub executed: usize,
    /// Jobs that panicked (spawned jobs are caught so the worker
    /// survives; `scoped` re-raises after its batch completes).
    pub panicked: usize,
}

struct Shared {
    /// Process-unique pool identity, matched against the thread-local
    /// worker registration so `submit` can route to the local deque.
    id: usize,
    /// External submissions (FIFO).
    injector: Mutex<VecDeque<Job>>,
    /// Per-worker deques: owner pushes/pops back (LIFO), thieves pop
    /// front (FIFO).
    locals: Vec<Mutex<VecDeque<Job>>>,
    /// Queued-job balance. Signed: a job's pop may be counted before
    /// its push on another thread; transient negatives are harmless.
    /// `> 0` keeps workers scanning instead of parking.
    pending: AtomicIsize,
    /// Parking lot: the mutex carries no data, it only serialises the
    /// empty-check/wait against the submitter's lock-drop/notify.
    idle: Mutex<()>,
    cv: Condvar,
    shutdown: AtomicBool,
    injected: AtomicUsize,
    local_pushes: AtomicUsize,
    steals: AtomicUsize,
    executed: AtomicUsize,
    panicked: AtomicUsize,
}

thread_local! {
    /// (pool id, worker index) when the current thread is a pool worker.
    static WORKER: Cell<Option<(usize, usize)>> = const { Cell::new(None) };
}

fn next_pool_id() -> usize {
    static IDS: AtomicUsize = AtomicUsize::new(1);
    IDS.fetch_add(1, Ordering::Relaxed)
}

/// Fixed set of worker threads scheduled by work stealing.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn a pool with `threads` workers (at least one).
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            id: next_pool_id(),
            injector: Mutex::new(VecDeque::new()),
            locals: (0..threads).map(|_| Mutex::new(VecDeque::new())).collect(),
            pending: AtomicIsize::new(0),
            idle: Mutex::new(()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            injected: AtomicUsize::new(0),
            local_pushes: AtomicUsize::new(0),
            steals: AtomicUsize::new(0),
            executed: AtomicUsize::new(0),
            panicked: AtomicUsize::new(0),
        });
        let workers = (0..threads)
            .map(|idx| {
                let sh = shared.clone();
                std::thread::spawn(move || worker_loop(sh, idx))
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// The process-wide pool, sized to the available parallelism (min 2).
    pub fn global() -> &'static ThreadPool {
        static POOL: OnceLock<ThreadPool> = OnceLock::new();
        POOL.get_or_init(|| {
            let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
            ThreadPool::new(n.max(2))
        })
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Snapshot the scheduler counters.
    pub fn stats(&self) -> ThreadPoolStats {
        ThreadPoolStats {
            injected: self.shared.injected.load(Ordering::Relaxed),
            local_pushes: self.shared.local_pushes.load(Ordering::Relaxed),
            steals: self.shared.steals.load(Ordering::Relaxed),
            executed: self.shared.executed.load(Ordering::Relaxed),
            panicked: self.shared.panicked.load(Ordering::Relaxed),
        }
    }

    /// Run `job` on the pool (fire-and-forget). A panicking job is
    /// caught and counted ([`ThreadPoolStats::panicked`]); the worker
    /// survives. Jobs still queued when the pool drops are drained, not
    /// lost.
    pub fn spawn(&self, job: impl FnOnce() + Send + 'static) {
        self.submit(Box::new(job));
    }

    fn submit(&self, job: Job) {
        let sh = &self.shared;
        // A worker of *this* pool pushes to its own deque (uncontended
        // in steady state); everyone else goes through the injector.
        let local = WORKER
            .with(|w| w.get())
            .and_then(|(pid, idx)| (pid == sh.id).then_some(idx));
        match local {
            Some(idx) => {
                sh.locals[idx].lock().unwrap().push_back(job);
                sh.local_pushes.fetch_add(1, Ordering::Relaxed);
            }
            None => {
                sh.injector.lock().unwrap().push_back(job);
                sh.injected.fetch_add(1, Ordering::Relaxed);
            }
        }
        sh.pending.fetch_add(1, Ordering::SeqCst);
        // Lock-drop/notify handshake: a worker that read `pending == 0`
        // holds `idle` until it is inside `cv.wait`, so acquiring (and
        // immediately releasing) the lock here guarantees the notify
        // cannot race into the gap between its check and its wait.
        drop(sh.idle.lock().unwrap());
        sh.cv.notify_one();
    }

    /// Run every job to completion, blocking the caller until the last
    /// one has finished. Jobs may borrow from the caller's stack; the
    /// borrow is sound because this function never returns (panic
    /// included) before every job has executed.
    pub fn scoped<'a>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'a>>) {
        if jobs.is_empty() {
            return;
        }
        let latch = Arc::new(Latch::new(jobs.len()));
        let panicked = Arc::new(AtomicBool::new(false));
        for job in jobs {
            // SAFETY: `latch.wait()` below blocks until this job has run
            // (the latch counts down even when the job panics), so every
            // borrow captured in `job` outlives its use on the worker.
            let job: Box<dyn FnOnce() + Send + 'static> = unsafe { std::mem::transmute(job) };
            let latch = latch.clone();
            let panicked = panicked.clone();
            self.submit(Box::new(move || {
                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                    panicked.store(true, Ordering::Relaxed);
                }
                latch.count_down();
            }));
        }
        latch.wait();
        if panicked.load(Ordering::Relaxed) {
            panic!("thread-pool job panicked");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        drop(self.shared.idle.lock().unwrap());
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Shared {
    /// Claim one job: own deque LIFO, then injector FIFO, then steal
    /// FIFO from siblings (scan order rotated per worker so thieves
    /// spread across victims instead of converging on worker 0).
    fn find_job(&self, idx: usize) -> Option<Job> {
        if let Some(j) = self.locals[idx].lock().unwrap().pop_back() {
            return Some(j);
        }
        if let Some(j) = self.injector.lock().unwrap().pop_front() {
            return Some(j);
        }
        let n = self.locals.len();
        for off in 1..n {
            let victim = (idx + off) % n;
            if let Some(j) = self.locals[victim].lock().unwrap().pop_front() {
                self.steals.fetch_add(1, Ordering::Relaxed);
                return Some(j);
            }
        }
        None
    }
}

fn worker_loop(sh: Arc<Shared>, idx: usize) {
    WORKER.with(|w| w.set(Some((sh.id, idx))));
    loop {
        if let Some(job) = sh.find_job(idx) {
            sh.pending.fetch_sub(1, Ordering::SeqCst);
            if catch_unwind(AssertUnwindSafe(job)).is_err() {
                sh.panicked.fetch_add(1, Ordering::Relaxed);
            }
            sh.executed.fetch_add(1, Ordering::Relaxed);
            continue;
        }
        let g = sh.idle.lock().unwrap();
        if sh.pending.load(Ordering::SeqCst) > 0 {
            // A submission landed between the scan and the lock; a
            // brief re-scan also covers a sibling mid-pop (its
            // decrement lags its dequeue by a few instructions).
            continue;
        }
        if sh.shutdown.load(Ordering::SeqCst) {
            // pending <= 0: every submitted job has been claimed, so
            // shutdown loses nothing.
            return;
        }
        let _unused = sh.cv.wait(g).unwrap();
    }
}

/// Count-down latch: `wait` blocks until `count_down` has been called
/// the initial-count number of times.
struct Latch {
    state: Mutex<usize>,
    cv: Condvar,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch { state: Mutex::new(n), cv: Condvar::new() }
    }

    fn count_down(&self) {
        let mut g = self.state.lock().unwrap();
        *g -= 1;
        if *g == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut g = self.state.lock().unwrap();
        while *g > 0 {
            g = self.cv.wait(g).unwrap();
        }
    }
}

// ---------------------------------------------------------------------
// Object recycling: ObjectPool + Recycler
// ---------------------------------------------------------------------

/// Counters of an [`ObjectPool`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ObjectPoolStats {
    /// Checkouts served from the idle shelf.
    pub hits: usize,
    /// Checkouts that constructed a fresh object.
    pub misses: usize,
    /// Objects checked back in.
    pub returns: usize,
    /// Returns rejected by the idle bound (object dropped instead).
    pub dropped: usize,
}

/// A pool of reusable objects. [`ObjectPool::checkout`] pops an idle
/// object (or builds one with the constructor) and wraps it in a
/// [`Recycler`] that checks it back in on drop — so anything with
/// amortised internal capacity (collections, buffers) keeps that
/// capacity warm across uses instead of re-allocating per use.
pub struct ObjectPool<T: Send> {
    idle: Mutex<Vec<T>>,
    make: Box<dyn Fn() -> T + Send + Sync>,
    max_idle: usize,
    hits: AtomicUsize,
    misses: AtomicUsize,
    returns: AtomicUsize,
    dropped: AtomicUsize,
}

impl<T: Send> ObjectPool<T> {
    /// Pool with a default idle bound of 64 objects.
    pub fn new(make: impl Fn() -> T + Send + Sync + 'static) -> Arc<ObjectPool<T>> {
        Self::with_max_idle(make, 64)
    }

    /// Pool keeping at most `max_idle` objects parked; returns beyond
    /// the bound drop the object (its memory goes back to its context).
    pub fn with_max_idle(
        make: impl Fn() -> T + Send + Sync + 'static,
        max_idle: usize,
    ) -> Arc<ObjectPool<T>> {
        Arc::new(ObjectPool {
            idle: Mutex::new(Vec::new()),
            make: Box::new(make),
            max_idle,
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            returns: AtomicUsize::new(0),
            dropped: AtomicUsize::new(0),
        })
    }

    /// Draw an object; it returns to the pool when the handle drops.
    /// Takes the `Arc` handle by value — clone it to keep the pool:
    /// `pool.clone().checkout()`.
    pub fn checkout(self: Arc<Self>) -> Recycler<T> {
        let recycled = self.idle.lock().unwrap().pop();
        let item = match recycled {
            Some(t) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                t
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                (self.make)()
            }
        };
        Recycler { item: Some(item), pool: self }
    }

    /// Objects currently parked.
    pub fn idle(&self) -> usize {
        self.idle.lock().unwrap().len()
    }

    /// Snapshot the counters.
    pub fn stats(&self) -> ObjectPoolStats {
        ObjectPoolStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            returns: self.returns.load(Ordering::Relaxed),
            dropped: self.dropped.load(Ordering::Relaxed),
        }
    }
}

impl<T: Send> std::fmt::Debug for ObjectPool<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        write!(f, "ObjectPool(idle={} {s:?})", self.idle())
    }
}

/// RAII checkout handle: derefs to the pooled object and checks it back
/// in on drop (unless [`Recycler::detach`]ed).
pub struct Recycler<T: Send> {
    item: Option<T>,
    pool: Arc<ObjectPool<T>>,
}

impl<T: Send> Recycler<T> {
    /// Take the object out for good; it will not return to the pool.
    pub fn detach(mut self) -> T {
        self.item.take().expect("recycler item present until drop")
    }
}

impl<T: Send> Deref for Recycler<T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.item.as_ref().expect("recycler item present until drop")
    }
}

impl<T: Send> DerefMut for Recycler<T> {
    fn deref_mut(&mut self) -> &mut T {
        self.item.as_mut().expect("recycler item present until drop")
    }
}

impl<T: Send> Drop for Recycler<T> {
    fn drop(&mut self) {
        if let Some(t) = self.item.take() {
            let mut g = self.pool.idle.lock().unwrap();
            if g.len() < self.pool.max_idle {
                self.pool.returns.fetch_add(1, Ordering::Relaxed);
                g.push(t);
            } else {
                self.pool.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let hits = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..64)
            .map(|_| {
                Box::new(|| {
                    hits.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.scoped(jobs);
        assert_eq!(hits.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn jobs_may_borrow_stack_data() {
        let pool = ThreadPool::new(3);
        let data: Vec<u64> = (0..1000).collect();
        let sums: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|c| {
                let chunk = &data[c * 250..(c + 1) * 250];
                let slot = &sums[c];
                Box::new(move || {
                    let s: u64 = chunk.iter().sum();
                    slot.store(s as usize, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.scoped(jobs);
        let total: usize = sums.iter().map(|s| s.load(Ordering::Relaxed)).sum();
        assert_eq!(total as u64, (0..1000u64).sum());
    }

    #[test]
    #[should_panic(expected = "thread-pool job panicked")]
    fn panics_propagate_after_batch_completes() {
        let pool = ThreadPool::new(2);
        let ok = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
            Box::new(|| panic!("boom")),
            Box::new(|| {
                ok.fetch_add(1, Ordering::Relaxed);
            }),
        ];
        pool.scoped(jobs);
    }

    #[test]
    fn global_pool_has_multiple_workers() {
        assert!(ThreadPool::global().workers() >= 2);
    }

    fn wait_until(deadline_ms: u64, cond: impl Fn() -> bool) {
        let deadline = std::time::Instant::now() + std::time::Duration::from_millis(deadline_ms);
        while !cond() {
            assert!(std::time::Instant::now() < deadline, "timed out waiting for condition");
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
    }

    #[test]
    fn spawn_loses_no_tasks_on_shutdown() {
        let done = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(3);
            for _ in 0..200 {
                let d = done.clone();
                pool.spawn(move || {
                    d.fetch_add(1, Ordering::Relaxed);
                });
            }
            // Drop drains every queued job before joining the workers.
        }
        assert_eq!(done.load(Ordering::Relaxed), 200);
    }

    #[test]
    fn worker_submissions_go_local_and_get_stolen() {
        let pool = Arc::new(ThreadPool::new(4));
        let done = Arc::new(AtomicUsize::new(0));
        let p2 = pool.clone();
        let d2 = done.clone();
        // One producer job fans out 64 slow children from inside the
        // pool: they land on the producer's own deque, and the three
        // idle siblings can only make progress by stealing them.
        pool.spawn(move || {
            for _ in 0..64 {
                let d = d2.clone();
                p2.spawn(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    d.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        wait_until(10_000, || done.load(Ordering::Relaxed) == 64);
        let s = pool.stats();
        assert!(s.local_pushes >= 64, "children not pushed locally: {s:?}");
        assert!(s.steals > 0, "no sibling stole from the producer's deque: {s:?}");
        assert_eq!(s.panicked, 0);
    }

    #[test]
    fn spawned_panics_are_counted_and_workers_survive() {
        let pool = ThreadPool::new(1);
        pool.spawn(|| panic!("boom (expected; spawned-panic test)"));
        let done = Arc::new(AtomicUsize::new(0));
        let d = done.clone();
        pool.spawn(move || {
            d.fetch_add(1, Ordering::Relaxed);
        });
        wait_until(10_000, || done.load(Ordering::Relaxed) == 1);
        assert!(pool.stats().panicked >= 1);
    }

    #[test]
    fn sequential_batches_reuse_workers() {
        let pool = ThreadPool::new(2);
        for round in 0..10 {
            let hit = AtomicUsize::new(0);
            pool.scoped(vec![Box::new(|| {
                hit.fetch_add(1, Ordering::Relaxed);
            }) as Box<dyn FnOnce() + Send + '_>]);
            assert_eq!(hit.load(Ordering::Relaxed), 1, "round {round}");
        }
    }

    #[test]
    fn object_pool_recycles_and_bounds_idle() {
        let made = Arc::new(AtomicUsize::new(0));
        let m = made.clone();
        let pool = ObjectPool::with_max_idle(
            move || {
                m.fetch_add(1, Ordering::Relaxed);
                Vec::<u8>::with_capacity(1024)
            },
            1,
        );
        {
            let mut a = pool.clone().checkout();
            a.push(7);
            let _b = pool.clone().checkout(); // second live object
        } // both return; idle bound 1 keeps one, drops one
        assert_eq!(made.load(Ordering::Relaxed), 2);
        assert_eq!(pool.idle(), 1);
        let s = pool.stats();
        assert_eq!((s.hits, s.misses, s.returns, s.dropped), (0, 2, 1, 1));
        // The survivor comes back warm (capacity intact, content stale —
        // callers own the reset policy).
        let c = pool.clone().checkout();
        assert!(c.capacity() >= 1024);
        assert_eq!(made.load(Ordering::Relaxed), 2);
        assert_eq!(pool.stats().hits, 1);
        let detached = c.detach();
        drop(detached);
        assert_eq!(pool.idle(), 0, "detached objects do not return");
    }

    /// Thread-pool + memory-pool contention stress: many scoped workers
    /// hammering one byte pool (PoolContext) and one object pool at
    /// once. Run via `ci.sh` with `MARIONETTE_STRESS=1` (or
    /// `cargo test -- --ignored`).
    #[test]
    #[ignore = "stress target; run with --ignored (ci.sh MARIONETTE_STRESS=1)"]
    fn thread_and_memory_pool_contention_stress() {
        use crate::marionette::buffer::ContextAwareVec;
        use crate::marionette::memory::{CountingInfo, Pool, PoolContext, PoolInfo};

        type Ctx = PoolContext<crate::marionette::memory::CountingContext>;

        let inner = CountingInfo::default();
        let bytes = PoolInfo(Pool::<crate::marionette::memory::CountingContext>::with_config(
            inner.clone(),
            8 << 20, // tight high water: trimming under contention
        ));
        let objects = {
            let info = bytes.clone();
            ObjectPool::with_max_idle(move || ContextAwareVec::<u64, Ctx>::new_in(info.clone()), 16)
        };

        let tp = ThreadPool::new(8);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..64)
            .map(|j| {
                let objects = objects.clone();
                let bytes = bytes.clone();
                Box::new(move || {
                    for round in 0..50 {
                        // Object-pool churn: grow a recycled vec to a
                        // job-dependent size, verify its tail.
                        let n = 64 + 37 * ((j + round) % 17);
                        let mut v = objects.clone().checkout();
                        v.clear();
                        for i in 0..n {
                            v.push((j * 1_000_000 + i) as u64);
                        }
                        assert_eq!(v[n - 1], (j * 1_000_000 + n - 1) as u64);
                        // Byte-pool churn: a short-lived buffer per round.
                        let scratch = ContextAwareVec::<u64, Ctx>::with_capacity_in(
                            n,
                            bytes.clone(),
                        );
                        assert!(scratch.capacity() >= n);
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        tp.scoped(jobs);

        // Scratch buffers all returned; the only blocks still checked
        // out are the ones held by idle pooled vecs (one buffer each).
        assert_eq!(bytes.0.outstanding(), objects.idle());
        // Release the object pool, then the byte pool: everything must
        // flow back to the counting heap with nothing leaked.
        drop(objects);
        assert_eq!(bytes.0.outstanding(), 0);
        drop(bytes);
        assert_eq!(inner.0.live_allocs(), 0, "leaked inner allocations");
        assert_eq!(inner.0.live_bytes(), 0, "leaked inner bytes");
    }
}

//! Mini property-testing framework (substrate: proptest is not vendored).
//!
//! Runs a closure over many seeded-random cases; on failure it reports the
//! failing case number and seed so the case can be replayed. Includes a
//! simple integer-shrinking pass for `Vec`-shaped inputs via
//! [`Cases::shrinkable`]. Used by the invariant tests in
//! `rust/tests/prop_marionette.rs`.

use super::rng::Rng;

/// Property-test driver: `CASES` seeded cases per property.
pub struct Cases {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Cases {
    fn default() -> Self {
        // Seed can be pinned for replay: MARIONETTE_PROP_SEED=1234
        let seed = std::env::var("MARIONETTE_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        Cases { cases: 64, seed }
    }
}

impl Cases {
    pub fn new(cases: usize) -> Self {
        Cases { cases, ..Default::default() }
    }

    /// Check `prop` on `self.cases` random cases. `prop` returns
    /// `Err(description)` to fail. Panics with the seed on failure.
    pub fn check<F>(&self, name: &str, mut prop: F)
    where
        F: FnMut(&mut Rng) -> Result<(), String>,
    {
        for case in 0..self.cases {
            let case_seed = self.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B9);
            let mut rng = Rng::seed_from_u64(case_seed);
            if let Err(msg) = prop(&mut rng) {
                panic!(
                    "property {name:?} failed on case {case} \
                     (replay: MARIONETTE_PROP_SEED={}): {msg}",
                    self.seed
                );
            }
        }
    }

    /// Check a property driven by a generated `Vec<u64>` *program* (e.g. a
    /// sequence of operations). On failure, greedily shrinks the program
    /// (removing chunks, then halving values) and reports the smallest
    /// failing program found.
    pub fn shrinkable<F>(&self, name: &str, max_len: usize, mut prop: F)
    where
        F: FnMut(&[u64]) -> Result<(), String>,
    {
        for case in 0..self.cases {
            let case_seed = self.seed.wrapping_add(case as u64).wrapping_mul(0x2545F491);
            let mut rng = Rng::seed_from_u64(case_seed);
            let len = rng.range_usize(0, max_len + 1);
            let program: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            if let Err(first) = prop(&program) {
                let (small, last) = shrink(&program, first, &mut prop);
                panic!(
                    "property {name:?} failed on case {case} \
                     (replay: MARIONETTE_PROP_SEED={}); shrunk program \
                     ({} ops): {:?}: {last}",
                    self.seed,
                    small.len(),
                    &small[..small.len().min(16)],
                );
            }
        }
    }
}

fn shrink<F>(program: &[u64], first_msg: String, prop: &mut F) -> (Vec<u64>, String)
where
    F: FnMut(&[u64]) -> Result<(), String>,
{
    let mut best = program.to_vec();
    let mut msg = first_msg;
    // Pass 1: remove halves/quarters/single elements.
    let mut chunk = (best.len() / 2).max(1);
    while chunk >= 1 {
        let mut i = 0;
        while i < best.len() {
            let mut cand = best.clone();
            let end = (i + chunk).min(cand.len());
            cand.drain(i..end);
            match prop(&cand) {
                Err(m) => {
                    best = cand;
                    msg = m;
                    // retry same position
                }
                Ok(()) => i += chunk,
            }
        }
        if chunk == 1 {
            break;
        }
        chunk /= 2;
    }
    // Pass 2: shrink values toward zero.
    for i in 0..best.len() {
        while best[i] > 0 {
            let mut cand = best.clone();
            cand[i] /= 2;
            match prop(&cand) {
                Err(m) => {
                    best = cand;
                    msg = m;
                }
                Ok(()) => break,
            }
        }
    }
    (best, msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        Cases::new(32).check("u64-roundtrip", |rng| {
            let x = rng.next_u64();
            if x.rotate_left(13).rotate_right(13) == x {
                Ok(())
            } else {
                Err("rotation broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "always-fails")]
    fn failing_property_panics_with_seed() {
        Cases::new(4).check("always-fails", |_| Err("always-fails".into()));
    }

    #[test]
    fn shrink_finds_minimal_program() {
        // Property: fails iff program contains a value >= 100.
        let mut calls = 0usize;
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            Cases::new(8).shrinkable("has-big", 64, |p| {
                calls += 1;
                if p.iter().any(|&x| x >= 100) {
                    Err("big value".into())
                } else {
                    Ok(())
                }
            });
        }));
        // Some case contains a big value with overwhelming probability;
        // the shrunk program should be a single element in [100, 200).
        let err = res.unwrap_err();
        let s = err.downcast_ref::<String>().unwrap();
        assert!(s.contains("1 ops"), "{s}");
    }
}

#!/usr/bin/env bash
# CI entry point: format, lint, build, test — Rust tier-1 plus the
# Python kernel tests when a pytest-capable interpreter is present.
# Everything runs offline against the image's vendored crate set.
set -euo pipefail
cd "$(dirname "$0")"

# rustfmt/clippy are rustup components that minimal offline images may
# lack. Skip those stages loudly rather than aborting before the tier-1
# build+test gate ever runs — the gate below is the one that must pass.
if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --all -- --check
else
    echo "!! SKIPPING cargo fmt: rustfmt component not installed" >&2
    echo "!! (rustup component add rustfmt to enable this stage)" >&2
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy (-D warnings) =="
    cargo clippy --workspace --all-targets -- -D warnings
else
    echo "!! SKIPPING cargo clippy: clippy component not installed" >&2
    echo "!! (rustup component add clippy to enable this stage)" >&2
fi

echo "== tier-1: cargo build --release && cargo test -q =="
cargo build --release
cargo test -q

echo "== tier-1 gate: pooled-memory test files =="
# The memory-subsystem suites must exist and pass by name (guards
# against the files being dropped while the blanket run stays green).
cargo test -q --test memory_conformance
cargo test -q --test transfer_matrix
cargo test -q --test pipeline_integration
cargo test -q --test bench_report_guard
cargo test -q --test coordinator_scale
cargo test -q --test prop_marionette
cargo test -q --test chaos
cargo test -q --test wire_roundtrip

echo "== saturate-smoke: worker scaling + tail latency =="
# Drives the sharded coordinator at 1/2/4 host workers; the command
# itself fails if events/s at the highest worker count drops below
# 0.8x the single-worker rate (catastrophic scaling loss).
cargo run --release -- saturate --events 20000 --workers 1,2,4 --quick \
    --out BENCH_saturate.json

echo "== autotune-smoke: AIMD controller + access-pattern heatmaps =="
# The adaptive saturate run fails if the controller never moves the
# batch bound, if adaptive throughput collapses below fixed dispatch,
# or if p99 overshoots the (generous smoke) target by >10%; the
# autotune run fails unless every route produces a non-empty access
# tape, and must leave the heatmap CSV behind.
cargo run --release -- saturate --adaptive --events 4000 --workers 2 \
    --quick --p99-target-us 2000000 --out BENCH_adaptive.json
cargo run --release -- autotune --quick
test -f rust/bench_results/autotune_heatmap.csv

echo "== chaos-smoke: kill a device worker mid-run, lose nothing =="
# Seeded fault injection (DESIGN.md §10): the device worker is killed
# at the 50th dequeue; the command fails unless every event lands in
# exactly one of {completed, quarantined} and every completed event
# matches the clean run's golden output.
cargo run --release -- chaos --quick --seed 7 --kill-device-at 50

echo "== ingest-smoke: 2 ingest processes -> 1 reconstruction over a socket =="
# Real multi-process run (DESIGN.md §11): two striped ingest processes
# frame the seeded event stream onto a Unix socket; the serve process
# reassembles, attaches frames zero-copy, and exits nonzero unless the
# result is exactly-once AND bit-identical to the in-process golden.
INGEST_SOCK="$(mktemp -u /tmp/marionette-ingest-XXXXXX.sock)"
cargo run --release -- serve --socket "$INGEST_SOCK" --events 60 --procs 2 &
SERVE_PID=$!
cargo run --release -- ingest --socket "$INGEST_SOCK" --events 60 --procs 2 --index 0 &
INGEST0_PID=$!
cargo run --release -- ingest --socket "$INGEST_SOCK" --events 60 --procs 2 --index 1 &
INGEST1_PID=$!
wait "$INGEST0_PID"
wait "$INGEST1_PID"
wait "$SERVE_PID"
rm -f "$INGEST_SOCK"

echo "== bench-smoke: reporter --quick, gated vs BENCH_baseline.json =="
# Emits BENCH_run.json (machine-readable trajectory, DESIGN.md §7) and
# fails if any gated series regresses beyond the baseline's tolerance.
cargo run --release -- bench-report --quick \
    --out BENCH_run.json --gate BENCH_baseline.json

echo "== public-API smoke: quickstart example + doc tests =="
# The redesigned interface surface (fluent builder, borrowed views,
# conversion sugar) is exercised end-to-end by the quickstart example
# and by the runnable doc examples on every run.
cargo run --release --example quickstart
cargo test -q --doc

if [[ "${MARIONETTE_STRESS:-0}" == "1" ]]; then
    echo "== stress: thread-pool + memory-pool contention (--ignored) =="
    cargo test -q --release thread_and_memory_pool_contention_stress -- --ignored
fi

echo "== python tests =="
if command -v python3 >/dev/null 2>&1 && python3 -c "import pytest" >/dev/null 2>&1; then
    # The `compile` package is imported relative to python/, so run
    # from there. Property-based modules need hypothesis, which some
    # images lack — skip just those when it is absent.
    pushd python >/dev/null
    pytest_args=(tests -q)
    if ! python3 -c "import hypothesis" >/dev/null 2>&1; then
        echo "hypothesis unavailable; skipping property-based modules"
        pytest_args+=(--ignore tests/test_kernel.py --ignore tests/test_model.py)
    fi
    python3 -m pytest "${pytest_args[@]}"
    popd >/dev/null
else
    echo "pytest unavailable; skipping python tests"
fi

echo "CI OK"

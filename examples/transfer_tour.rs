//! Transfer tour (§VII-A): memory contexts, context-info updates,
//! cross-context collection transfers, DMA accounting and the
//! specialized-transfer extension point.
//!
//!     cargo run --release --example transfer_tour

use std::sync::atomic::Ordering;

use marionette::edm::generator::{EventConfig, EventGenerator};
use marionette::edm::handwritten::HwSensorsAoS;
use marionette::edm::SensorCollection;
use marionette::prelude::{
    AoS, AoSoA, ArenaContext, ArenaInfo, CountingContext, CountingInfo, SoAVec, StagingContext,
    StagingInfo, TransferPriority,
};

/// The paper's `TransferSpecification` extension point: a user-written
/// fast path from a *pre-existing external type* (the handwritten AoS)
/// straight into a Marionette collection, bypassing the generic ladder.
fn specialized_from_hw(src: &HwSensorsAoS, dst: &mut SensorCollection<SoAVec>) -> TransferPriority {
    dst.clear();
    dst.set_rows(src.rows);
    dst.set_cols(src.cols);
    dst.set_event_id(src.event_id);
    dst.resize(src.len());
    for (i, rec) in src.data.iter().enumerate() {
        dst.set_type_id(i, rec.type_id);
        dst.set_counts(i, rec.counts);
        dst.set_energy(i, rec.energy);
        dst.set_noise(i, rec.noise);
        dst.set_sig(i, rec.sig);
        dst.set_noisy(i, rec.noisy);
        dst.set_param_a(i, rec.param_a);
        dst.set_param_b(i, rec.param_b);
        dst.set_noise_a(i, rec.noise_a);
        dst.set_noise_b(i, rec.noise_b);
    }
    TransferPriority::Specialized
}

fn main() {
    let ev = EventGenerator::new(EventConfig::grid(64, 64, 4), 9).generate();

    // --- counting context: watch what a collection does ----------------
    let count_info = CountingInfo::default();
    let mut counted = SensorCollection::build()
        .layout::<SoAVec<CountingContext>>()
        .context(count_info.clone())
        .finish();
    ev.fill_collection(&mut counted);
    println!(
        "counting ctx: {} allocations, {} bytes",
        count_info.0.allocs.load(Ordering::Relaxed),
        count_info.0.bytes_allocated.load(Ordering::Relaxed)
    );

    // --- update_memory_context_info: re-home live storage --------------
    let fresh_info = CountingInfo::default();
    counted.update_memory_context_info(fresh_info.clone());
    assert_eq!(counted.counts(10), ev.counts[10]);
    println!(
        "after update_memory_context_info: new ctx owns {} allocations",
        fresh_info.0.allocs.load(Ordering::Relaxed)
    );

    // --- arena context: bump allocation for per-event collections ------
    let arena = ArenaInfo::default();
    let mut scratch = SensorCollection::build()
        .layout::<AoS<ArenaContext>>()
        .context(arena.clone())
        .finish();
    ev.fill_collection(&mut scratch);
    println!("arena ctx: {} bytes parked after fill", arena.0.capacity());

    // --- staging context: the H2D boundary with DMA accounting ---------
    let staging = StagingInfo::default();
    let mut staged = SensorCollection::build()
        .layout::<SoAVec<StagingContext>>()
        .context(staging.clone())
        .finish();
    let up = counted.stage_into(&mut staged);
    println!(
        "host->staging transfer used rung {:?}: {} H2D bytes, {} calls",
        up.priority,
        staging.counters.h2d_bytes.load(Ordering::Relaxed),
        staging.counters.h2d_calls.load(Ordering::Relaxed)
    );

    // --- layout ladder: dense, strided and element-wise rungs ----------
    let mut aos = SensorCollection::<AoS>::new();
    let rung = counted.stage_into(&mut aos).priority;
    println!("soa-vec -> aos rung: {rung:?}");
    let mut blocked = SensorCollection::<AoSoA<8>>::new();
    let rung = aos.stage_into(&mut blocked).priority;
    println!("aos -> aosoa rung: {rung:?}");

    // --- specialized transfer from an external type ---------------------
    let mut hw = HwSensorsAoS::default();
    ev.fill_hw_aos(&mut hw);
    marionette::edm::calib::calibrate_hw_aos(&mut hw);
    let mut from_hw = SensorCollection::<SoAVec>::new();
    let rung = specialized_from_hw(&hw, &mut from_hw);
    println!("handwritten-AoS -> marionette via {rung:?}");
    assert_eq!(from_hw.energy(100), hw.data[100].energy);

    // Everything agrees at the end — checked through the one borrowed
    // view interface rather than four accessor paths.
    let (vc, va, vb, vs) = (counted.view(), aos.view(), blocked.view(), staged.view());
    for i in (0..ev.num_sensors()).step_by(997) {
        assert_eq!(vc.counts(i), va.counts(i));
        assert_eq!(va.counts(i), vb.counts(i));
        assert_eq!(vs.counts(i), vb.counts(i));
    }
    println!("transfer_tour OK");
}

//! End-to-end driver: the full three-layer system on a real workload.
//!
//! Streams synthetic detector events through the coordinator — CPU
//! workers run the Marionette host algorithms, the device worker runs
//! the AOT-compiled JAX/Pallas executables via PJRT — and reports
//! throughput, latency and physics totals, plus a host-vs-device
//! cross-check on a sample of events. (EXPERIMENTS.md §E2E records a
//! reference run.)
//!
//!     cargo run --release --example atlas_pipeline -- [events] [grid]

use marionette::coordinator::pipeline::{process_device, process_host};
use marionette::coordinator::{run_pipeline, PipelineConfig, Route, RoutePolicy};
use marionette::edm::generator::{EventConfig, EventGenerator};
use marionette::runtime::{client, Engine};

fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let events: usize = args.next().map(|s| s.parse()).transpose()?.unwrap_or(64);
    let grid: usize = args.next().map(|s| s.parse()).transpose()?.unwrap_or(256);
    let deposits = (grid / 32).max(1).pow(2);

    println!("== ATLAS-like event pipeline ==");
    println!("device: {}", client::device_description());
    println!("workload: {events} events, {grid}x{grid} sensors, ~{deposits} deposits each");

    // Warm the device executable outside the measured run.
    let have_device = match Engine::load_default() {
        Ok(eng) => {
            let d = eng.warm("full_event", grid, grid);
            match d {
                Ok(d) => {
                    println!("device warmup (XLA compile): {d:?}");
                    true
                }
                Err(e) => {
                    println!("no device bucket for {grid}: {e:#}");
                    false
                }
            }
        }
        Err(e) => {
            println!("device unavailable: {e:#}");
            false
        }
    };

    // --- mixed host/device run through the coordinator -----------------
    let mut cfg = PipelineConfig::new(EventConfig::grid(grid, grid, deposits), events);
    cfg.device = have_device;
    cfg.policy = if have_device {
        // Split roughly evenly so both paths are exercised: half the
        // events are below the crossover only if grids differ, so route
        // by queue pressure instead.
        RoutePolicy::Auto { min_device_cells: 0, max_device_queue: 2 }
    } else {
        RoutePolicy::HostOnly
    };
    let report = run_pipeline(&cfg)?;
    println!("\n{}", report.report());

    let host_n = report.results.iter().filter(|r| r.route == Route::Host).count();
    let dev_n = report.results.len() - host_n;
    println!("routing split: {host_n} host / {dev_n} device");

    // --- physics cross-check: host and device agree per event -----------
    if have_device {
        let eng = Engine::load_default()?;
        let mut gen = EventGenerator::new(EventConfig::grid(grid, grid, deposits), cfg.seed);
        let mut checked = 0;
        for _ in 0..events.min(4) {
            let ev = gen.generate();
            let (hn, he) = process_host(&ev);
            let (dn, de, _) = process_device(&eng, &ev)?;
            assert_eq!(hn, dn, "particle count mismatch on event {}", ev.event_id);
            let rel = (he - de).abs() / he.abs().max(1.0);
            assert!(rel < 1e-3, "energy mismatch {rel} on event {}", ev.event_id);
            checked += 1;
        }
        println!("host/device physics cross-check: {checked} events OK");
    }

    // --- sanity: the stream had real physics in it ----------------------
    let total_particles = report.total_particles();
    assert!(
        total_particles >= events * deposits / 4,
        "suspiciously few particles: {total_particles}"
    );
    println!(
        "\n{} particles over {} events ({:.1}/event); {:.1} events/s end-to-end",
        total_particles,
        events,
        total_particles as f64 / events as f64,
        report.events_per_sec()
    );
    println!("atlas_pipeline OK");
    Ok(())
}

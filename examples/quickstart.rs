//! Quickstart: declare a collection, use every property kind, switch
//! layouts, transfer between memory contexts.
//!
//!     cargo run --release --example quickstart

use marionette::marionette::layout::{AoS, AoSoA, SoAVec};
use marionette::marionette::memory::{StagingContext, StagingInfo};
use marionette::marionette_collection;

// One declaration produces the typed collection, object proxies, owned
// objects, sub-group views and the compile-time property metadata
// (the analogue of the paper's MARIONETTE_DECLARE_* macros).
marionette_collection! {
    /// A toy track collection demonstrating every property kind.
    pub collection Tracks, object Track, record TrackRecord,
        columns TrackColumns, refs TrackRef / TrackMut,
        props TrackProps, schema "track" {
        per_item pt / set_pt / PT: f32;
        per_item charge / set_charge / CHARGE: i8;
        group fit / FitView / FitViewMut {
            per_item chi2 / set_chi2 / CHI2: f32;
            per_item ndf / set_ndf / NDF: i32;
        }
        array cov_diag / set_cov_diag / COV_DIAG: [f32; 3];
        jagged hits / set_hits / HITS: u32, prefix u32;
        global run_number / set_run_number / RUN_NUMBER: u64;
    }
}

fn main() {
    // --- build a collection in the default layout (SoA vectors) --------
    let mut tracks = Tracks::<SoAVec>::new();
    tracks.set_run_number(42);

    for i in 0..5 {
        let idx = tracks.push(&Track {
            pt: 10.0 * (i as f32 + 1.0),
            charge: if i % 2 == 0 { 1 } else { -1 },
            chi2: 1.1 * i as f32,
            ndf: 2 * i as i32,
            cov_diag: [0.1, 0.2, 0.3],
            hits: (0..=i as u32).collect(),
        });
        assert_eq!(idx, i);
    }

    // Element accessors, object proxies, sub-group views, jagged views.
    println!("run {}: {} tracks", tracks.run_number(), tracks.len());
    for t in tracks.iter() {
        println!(
            "  track {}: pt={:.1} q={} chi2/ndf={:.2}/{} hits={:?} cov0={}",
            t.index(),
            t.pt(),
            t.charge(),
            t.fit().chi2(),
            t.fit().ndf(),
            t.hits().to_vec(),
            t.cov_diag(0),
        );
    }

    // Mutation through proxies.
    let mut m = tracks.obj_mut(0);
    m.set_pt(99.0);
    m.fit().set_chi2(0.5);
    assert_eq!(tracks.pt(0), 99.0);

    // --- same interface, different layout: AoS records -----------------
    let mut aos = Tracks::<AoS>::new();
    aos.transfer_from(&tracks);
    assert_eq!(aos.pt(0), 99.0);
    assert_eq!(aos.hits(4).to_vec(), vec![0, 1, 2, 3, 4]);
    println!("AoS copy agrees; layout = {}", aos.layout_name());

    // --- blocked AoSoA, then back -- transfers compose ------------------
    let mut blocked = Tracks::<AoSoA<8>>::new();
    let rung = blocked.transfer_from(&aos);
    println!("AoS -> AoSoA used the {rung:?} transfer rung");

    // --- a different *memory context*: staging (DMA-accounted) ----------
    let staging_info = StagingInfo::default();
    let mut staged = Tracks::<SoAVec<StagingContext>>::new_in(staging_info.clone());
    staged.transfer_from(&blocked);
    println!(
        "upload to staging: {} H2D bytes in {} copies",
        staging_info
            .counters
            .h2d_bytes
            .load(std::sync::atomic::Ordering::Relaxed),
        staging_info
            .counters
            .h2d_calls
            .load(std::sync::atomic::Ordering::Relaxed),
    );

    // Vector-like ops keep jagged vectors consistent.
    let mut t = tracks;
    t.erase_items(1, 2);
    assert_eq!(t.len(), 3);
    assert_eq!(t.hits(1).to_vec(), vec![0, 1, 2, 3]);
    t.insert_items(1, 1);
    assert_eq!(t.hits(1).len(), 0);
    println!("insert/erase keep jagged prefix sums consistent");

    println!("quickstart OK");
}

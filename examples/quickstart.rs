//! Quickstart: declare a collection, build it fluently, use every
//! property kind, switch layouts with the conversion sugar, and attach
//! borrowed typed views to stores you don't own.
//!
//!     cargo run --release --example quickstart

use marionette::marionette_collection;
use marionette::prelude::{
    AoS, AoSoA, CountingContext, CountingInfo, SlicePlanes, SoAVec, StagingContext, StagingInfo,
};

// One declaration produces the typed collection, object proxies, owned
// objects, sub-group views, the borrowed source-erased views and the
// compile-time property metadata (the analogue of the paper's
// MARIONETTE_DECLARE_* macros).
marionette_collection! {
    /// A toy track collection demonstrating every property kind.
    pub collection Tracks, object Track, record TrackRecord,
        columns TrackColumns, refs TrackRef / TrackMut,
        views TracksView / TracksViewMut,
        props TrackProps, schema "track" {
        per_item pt / set_pt / PT: f32;
        per_item charge / set_charge / CHARGE: i8;
        group fit / FitView / FitViewMut {
            per_item chi2 / set_chi2 / CHI2: f32;
            per_item ndf / set_ndf / NDF: i32;
        }
        array cov_diag / set_cov_diag / COV_DIAG: [f32; 3];
        jagged hits / set_hits / HITS: u32, prefix u32;
        global run_number / set_run_number / RUN_NUMBER: u64;
    }
}

fn main() {
    // --- fluent build: layout, context and capacity in one chain -------
    let mut tracks = Tracks::build().capacity(8).finish(); // SoAVec<HostContext>
    tracks.set_run_number(42);

    for i in 0..5 {
        let idx = tracks.push(&Track {
            pt: 10.0 * (i as f32 + 1.0),
            charge: if i % 2 == 0 { 1 } else { -1 },
            chi2: 1.1 * i as f32,
            ndf: 2 * i as i32,
            cov_diag: [0.1, 0.2, 0.3],
            hits: (0..=i as u32).collect(),
        });
        assert_eq!(idx, i);
    }

    // Element accessors, object proxies, sub-group views, jagged views.
    println!("run {}: {} tracks", tracks.run_number(), tracks.len());
    for t in tracks.iter() {
        println!(
            "  track {}: pt={:.1} q={} chi2/ndf={:.2}/{} hits={:?} cov0={}",
            t.index(),
            t.pt(),
            t.charge(),
            t.fit().chi2(),
            t.fit().ndf(),
            t.hits().to_vec(),
            t.cov_diag(0),
        );
    }

    // Mutation through proxies.
    let mut m = tracks.obj_mut(0);
    m.set_pt(99.0);
    m.fit().set_chi2(0.5);
    assert_eq!(tracks.pt(0), 99.0);

    // --- borrowed typed views: the interface detached from ownership ---
    // `view()` is the owned special case; `TracksView::attach` takes ANY
    // schema-matching source (owned collection, pooled stage, slices).
    let v = tracks.view();
    let mean_pt: f32 = (0..v.len()).map(|i| v.pt(i)).sum::<f32>() / v.len() as f32;
    println!("view over owned store: mean pt = {mean_pt:.1}");
    assert_eq!(v.hits(4).to_vec(), vec![0, 1, 2, 3, 4]);

    // A source the collection never owned: plain slices bound into a
    // schema-shaped store (this is how downloaded device planes attach).
    let pt = [1.0f32, 2.0];
    let charge = [1i8, -1];
    let chi2 = [0.1f32, 0.2];
    let ndf = [3i32, 4];
    let cov0 = [9.0f32, 9.0];
    let cov1 = [8.0f32, 8.0];
    let cov2 = [7.0f32, 7.0];
    let prefix = [0u32, 2, 3];
    let hit_vals = [10u32, 11, 12];
    let planes = SlicePlanes::new(TrackProps::schema(), 2)
        .bind("pt", &pt)
        .unwrap()
        .bind("charge", &charge)
        .unwrap()
        .bind("chi2", &chi2)
        .unwrap()
        .bind("ndf", &ndf)
        .unwrap()
        .bind_lane("cov_diag", 0, &cov0)
        .unwrap()
        .bind_lane("cov_diag", 1, &cov1)
        .unwrap()
        .bind_lane("cov_diag", 2, &cov2)
        .unwrap()
        .bind("hits__prefix", &prefix)
        .unwrap()
        .bind("hits", &hit_vals)
        .unwrap()
        .set_global("run_number", 7u64)
        .unwrap();
    let external = TracksView::attach(&planes).expect("schema-checked attach");
    println!(
        "view over borrowed slices: run {} track0 hits {:?}",
        external.run_number(),
        external.hits(0).to_vec(),
    );
    assert_eq!(external.hits(0).to_vec(), vec![10, 11]);

    // --- conversion sugar: same interface, different layout ------------
    let aos = tracks.convert_to::<AoS>();
    assert_eq!(aos.pt(0), 99.0);
    assert_eq!(aos.hits(4).to_vec(), vec![0, 1, 2, 3, 4]);
    println!("convert_to agrees; layout = {}", aos.layout_name());

    // Builder with an explicit layout + context, then staged refills
    // through the cached transfer plan.
    let counting = CountingInfo::default();
    let mut blocked = Tracks::build()
        .layout::<AoSoA<8, CountingContext>>()
        .context(counting)
        .capacity(tracks.len())
        .finish();
    let stats = aos.stage_into(&mut blocked);
    println!(
        "AoS -> AoSoA staged {} bytes in {} ops via the {:?} rung",
        stats.bytes, stats.ops, stats.priority
    );

    // --- a different *memory context*: staging (DMA-accounted) ----------
    let staging_info = StagingInfo::default();
    let mut staged = Tracks::build()
        .layout::<SoAVec<StagingContext>>()
        .context(staging_info.clone())
        .finish();
    blocked.stage_into(&mut staged);
    println!(
        "upload to staging: {} H2D bytes in {} copies",
        staging_info
            .counters
            .h2d_bytes
            .load(std::sync::atomic::Ordering::Relaxed),
        staging_info
            .counters
            .h2d_calls
            .load(std::sync::atomic::Ordering::Relaxed),
    );

    // Vector-like ops keep jagged vectors consistent.
    let mut t = tracks;
    t.erase_items(1, 2);
    assert_eq!(t.len(), 3);
    assert_eq!(t.hits(1).to_vec(), vec![0, 1, 2, 3]);
    t.insert_items(1, 1);
    assert_eq!(t.hits(1).len(), 0);
    println!("insert/erase keep jagged prefix sums consistent");

    println!("quickstart OK");
}

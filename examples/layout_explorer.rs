//! Layout explorer: the paper's §III motivation — "the ability to
//! experiment with different data layouts may be useful for development
//! efforts and optimization" — as a runnable comparison.
//!
//! Runs the two host algorithms (calibrate = linear sweep touching all
//! fields; reconstruct = stencil with type-split tallies) over every
//! layout and prints a comparison table with relative factors.
//!
//!     cargo run --release --example layout_explorer -- [grid]

use std::time::Duration;

use marionette::bench_support::Harness;
use marionette::edm::generator::{EventConfig, EventGenerator};
use marionette::edm::{calib, reco};
use marionette::prelude::{AoS, AoSoA, SoABlob, SoAVec};

fn main() -> anyhow::Result<()> {
    let grid: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(256);
    let deposits = (grid / 32).max(1).pow(2);
    let ev = EventGenerator::new(EventConfig::grid(grid, grid, deposits), 3).generate();
    let h = Harness { runs: 15, keep: 5, warmup: 2 };

    println!("== layout explorer: {grid}x{grid}, {deposits} deposits ==\n");
    println!(
        "{:<10} {:>14} {:>14} {:>10}",
        "layout", "calibrate", "reconstruct", "particles"
    );

    let mut rows: Vec<(&str, Duration, Duration, usize)> = Vec::new();

    macro_rules! measure {
        ($label:expr, $layout:ty) => {{
            let mut col = ev.to_collection::<$layout>();
            let t_cal = h.measure(|| calib::calibrate_collection(&mut col));
            let mut n = 0;
            let t_rec = h.measure(|| {
                n = reco::reconstruct_collection(&col).len();
            });
            rows.push(($label, t_cal, t_rec, n));
        }};
    }

    measure!("soa-vec", SoAVec);
    measure!("aos", AoS);
    measure!("soa-blob", SoABlob);
    measure!("aosoa-4", AoSoA<4>);
    measure!("aosoa-8", AoSoA<8>);
    measure!("aosoa-16", AoSoA<16>);

    let base_cal = rows[0].1.as_secs_f64();
    let base_rec = rows[0].2.as_secs_f64();
    for (label, cal, rec, n) in &rows {
        println!(
            "{:<10} {:>11.1}us ({:>4.2}x) {:>9.1}us ({:>4.2}x) {:>6}",
            label,
            cal.as_secs_f64() * 1e6,
            cal.as_secs_f64() / base_cal,
            rec.as_secs_f64() * 1e6,
            rec.as_secs_f64() / base_rec,
            n
        );
    }

    // All layouts must agree on the physics.
    let counts: Vec<usize> = rows.iter().map(|r| r.3).collect();
    assert!(counts.windows(2).all(|w| w[0] == w[1]), "layouts disagree: {counts:?}");
    println!("\nall layouts reconstruct identical particle counts: {}", counts[0]);
    println!("layout_explorer OK");
    Ok(())
}
